"""Sparse- and bucketed-layout engine correctness — the acceptance contract
of the CSR and degree-bucketed refactors.

Four claims:

1. The sparse scan backend, the sparse Pallas tile backend, and the dense
   ``mhlj()`` matrix chain realize the SAME transition law on an irregular
   (CSR-built) graph — chi-square at ~4-sigma.
2. Scan and sparse-Pallas are BITWISE equal given the same key, including
   when ``max_degree`` is odd (not a multiple of any block/lane size) and
   W is not a multiple of ``block_w``.
3. ``layout="bucketed"`` (per-degree-bucket tiles, pallas AND its scan
   fallback) is BITWISE equal to the sparse and dense layouts on hub-heavy
   and trap-prone graphs, including bucket-boundary degrees — so the whole
   chi-square/stationary harness verifies the bucketed path for free.
4. The sparse and bucketed layouts are genuinely O(E)-resident: the full
   (n, max_deg) row table is never materialized on the live-rows path, and
   the bucketed engine carries no full-width tensor at all.
5. Per-step walk compaction (the fast bucketed dispatch: walks sorted by
   bucket id, tile passes at static capacity, overflow -> full-dispatch
   fallback) never changes a sampled walk — bitwise parity with
   layout="sparse" at adversarial shapes: W not a block_w multiple, all
   walks in one bucket, empty buckets, capacity overflow, and both
   bucket_factor ladders.
6. ``layout="ragged"`` (flat per-edge CDF, binary-search MH inversion,
   fused scalar-prefetch kernel) is BITWISE equal to every other layout
   per key — from a shared padded row table, from the flat numpy
   builders, and from a live lipschitz vector; on hub-heavy/trap-prone
   graphs, at bucket-boundary degrees, and at W values that are not
   block multiples — and its resident state is *exactly* O(E): every
   engine array is one-dimensional (no padded, no per-bucket table), and
   ``from_edges(layout="ragged")`` builds a graph that never carries a
   padded tensor at all.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MHLJParams,
    WalkEngine,
    barabasi_albert,
    dumbbell,
    lollipop,
    mh_importance,
    mh_importance_rows_bucketed,
    mh_importance_rows_ragged,
    mhlj,
    row_probs_padded,
    sbm,
)


@pytest.fixture(scope="module")
def setup():
    # irregular hub-heavy graph, built dense for the matrix-chain oracle;
    # the engine consumes its O(E) CSR twin
    g = barabasi_albert(48, 3, seed=1, layout="dense")
    csr = g.to_csr()
    lips = np.ones(g.n)
    lips[5] = 35.0  # trap node
    params = MHLJParams(0.25, 0.5, 3)
    rp = jnp.asarray(row_probs_padded(mh_importance(g, lips), g))
    return g, csr, lips, params, rp


def _engine(csr, params, rp, backend, layout="sparse", block_w=256):
    return WalkEngine.from_graph(
        csr, params, row_probs=rp, backend=backend, layout=layout,
        block_w=block_w,
    )


def _chi_square_stat(counts, probs, min_expected=10.0):
    """Pearson chi-square with small-expectation bins lumped together."""
    total = counts.sum()
    expected = probs * total
    big = expected >= min_expected
    obs = np.concatenate([counts[big], [counts[~big].sum()]])
    exp = np.concatenate([expected[big], [expected[~big].sum()]])
    keep = exp > 0
    obs, exp = obs[keep], exp[keep]
    stat = float(((obs - exp) ** 2 / exp).sum())
    return stat, len(obs) - 1


def test_sparse_backends_bitwise_equal_odd_max_degree(setup):
    """Scan and sparse-Pallas tiles agree bitwise on a CSR graph whose
    max_degree (7) is not a multiple of any block size, across W values
    that are not block multiples either."""
    _, _, _, params, _ = setup
    g = dumbbell(6, 3)  # clique bridge node: deg 7 — odd on purpose
    assert g.max_degree % 2 == 1
    csr = g.to_csr()
    lips = np.ones(g.n)
    lips[0] = 25.0
    rp = jnp.asarray(row_probs_padded(mh_importance(g, lips), g))
    key = jax.random.PRNGKey(0)
    for w, block_w in ((128, 64), (300, 128), (37, 256), (5, 4)):
        nodes = jnp.arange(w, dtype=jnp.int32) % csr.n
        n_s, h_s = _engine(csr, params, rp, "scan").step(key, nodes)
        n_p, h_p = _engine(
            csr, params, rp, "pallas", block_w=block_w
        ).step(key, nodes)
        np.testing.assert_array_equal(np.asarray(n_s), np.asarray(n_p))
        np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_p))


def test_sparse_and_dense_layouts_bitwise_equal(setup):
    """The sparse tile kernel and the legacy full-table kernel are the same
    transition, bit for bit."""
    _, csr, _, params, rp = setup
    key = jax.random.PRNGKey(2)
    nodes = jnp.arange(200, dtype=jnp.int32) % csr.n
    n_sp, h_sp = _engine(csr, params, rp, "pallas", layout="sparse").step(key, nodes)
    n_dn, h_dn = _engine(csr, params, rp, "pallas", layout="dense").step(key, nodes)
    np.testing.assert_array_equal(np.asarray(n_sp), np.asarray(n_dn))
    np.testing.assert_array_equal(np.asarray(h_sp), np.asarray(h_dn))


@pytest.mark.slow
def test_sparse_backends_match_dense_chain_chi_square(setup):
    """Empirical one-step law of the sparse scan backend, the sparse Pallas
    backend AND the bucketed layout vs the dense MHLJ matrix chain,
    chi-square at ~4-sigma, on the irregular BA graph."""
    g, csr, lips, params, rp = setup
    start = 5
    w = 30_000
    nodes = jnp.full((w,), start, jnp.int32)
    expected_row = mhlj(g, lips, params)[start]  # chained-Levy exact law

    for backend, layout, key in (
        ("scan", "sparse", 11),
        ("pallas", "sparse", 12),
        ("pallas", "bucketed", 13),
        ("pallas", "ragged", 14),
        ("scan", "ragged", 15),
    ):
        nxt, _ = _engine(csr, params, rp, backend, layout=layout).step(
            jax.random.PRNGKey(key), nodes
        )
        counts = np.bincount(np.asarray(nxt), minlength=csr.n).astype(np.float64)
        stat, dof = _chi_square_stat(counts, expected_row)
        crit = dof + 4.0 * np.sqrt(2.0 * dof)
        assert stat < crit, (
            f"{backend}/{layout}: chi2={stat:.1f} >= {crit:.1f} (dof={dof})"
        )


def test_sparse_layout_never_builds_full_table(setup, monkeypatch):
    """O(E) guarantee: with live Eq.-7 rows, neither sparse backend ever
    calls ``rows_table`` (the dense layout does — sanity-checked last)."""
    _, csr, lips, params, _ = setup
    lips_j = jnp.asarray(lips, jnp.float32)
    nodes = jnp.arange(32, dtype=jnp.int32) % csr.n

    def boom(self, lipschitz=None):
        raise AssertionError("sparse layout materialized the dense row table")

    monkeypatch.setattr(WalkEngine, "rows_table", boom)
    for backend in ("scan", "pallas"):
        eng = WalkEngine.from_graph(
            csr, params, backend=backend, layout="sparse"
        )
        nxt, hops = eng.step(jax.random.PRNGKey(3), nodes, lipschitz=lips_j)
        nxt = np.asarray(nxt)
        assert ((nxt >= 0) & (nxt < csr.n)).all()

    monkeypatch.undo()
    called = {}
    real = WalkEngine.rows_table

    def spying(self, lipschitz=None):
        called["yes"] = True
        return real(self, lipschitz)

    monkeypatch.setattr(WalkEngine, "rows_table", spying)
    eng = WalkEngine.from_graph(csr, params, backend="pallas", layout="dense")
    eng.step(jax.random.PRNGKey(4), nodes, lipschitz=lips_j)
    assert called.get("yes")


# ---------------------------------------------------------------------------
# Degree-bucketed layout parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "build",
    [
        lambda: barabasi_albert(80, 3, seed=3, layout="dense"),
        lambda: lollipop(16, 9),
    ],
)
def test_bucketed_layout_bitwise_equal_all_paths(build):
    """layout='bucketed' — both the per-bucket Pallas tile dispatch and its
    pure-jnp scan fallback — agrees bitwise with layout='sparse' and the
    scan oracle on a hub-heavy BA graph and the lollipop stressor, at W
    values that are not block multiples.  The bucketed engines are driven
    once from the full row table (exact column truncation) and once from
    the per-bucket numpy builders."""
    g = build()
    csr = g.to_csr()
    bg = csr.to_bucketed()
    assert len(bg.buckets) >= 2  # the test must actually dispatch
    lips = np.ones(g.n)
    lips[1] = 30.0
    params = MHLJParams(0.3, 0.5, 3)
    rp = jnp.asarray(row_probs_padded(mh_importance(g, lips), g))
    rows_b = mh_importance_rows_bucketed(bg, lips)
    for w, block_w, key_seed in ((37, 16, 0), (300, 128, 1), (129, 64, 2)):
        key = jax.random.PRNGKey(key_seed)
        nodes = jnp.arange(w, dtype=jnp.int32) % csr.n
        ref_n, ref_h = _engine(csr, params, rp, "scan").step(key, nodes)
        candidates = [
            _engine(csr, params, rp, "pallas", layout="sparse",
                    block_w=block_w),
            _engine(csr, params, rp, "pallas", layout="bucketed",
                    block_w=block_w),
            _engine(csr, params, rp, "scan", layout="bucketed"),
            WalkEngine.from_graph(
                bg, params, row_probs=rows_b, backend="pallas",
                block_w=block_w,
            ),
        ]
        for eng in candidates:
            n2, h2 = eng.step(key, nodes)
            np.testing.assert_array_equal(np.asarray(ref_n), np.asarray(n2))
            np.testing.assert_array_equal(np.asarray(ref_h), np.asarray(h2))


def test_bucketed_engine_carries_no_full_width_tensor():
    """The bucketed engine's resident state is O(E + Σ_b n_b·width_b): no
    (n, max_deg) table exists, and asking for one raises."""
    bg = barabasi_albert(100, 3, seed=5, layout="bucketed")
    params = MHLJParams(0.2, 0.5, 3)
    lips = jnp.ones(bg.n)
    eng = WalkEngine.from_graph(bg, params, lipschitz=lips)
    assert eng.layout == "bucketed"
    assert eng.neighbors is None and eng.row_probs is None
    with pytest.raises(ValueError, match="bucketed layout"):
        eng.rows_table()
    max_deg = bg.max_degree
    for b, nbrs in enumerate(eng.bucket_neighbors):
        assert nbrs.shape[1] == bg.buckets[b].width <= max_deg
    # live-rows path: steps stay in range without any precomputed rows
    eng_live = WalkEngine.from_graph(bg, params, backend="scan")
    nodes = jnp.arange(33, dtype=jnp.int32) % bg.n
    nxt, hops = eng_live.step(
        jax.random.PRNGKey(1), nodes, lipschitz=lips
    )
    nxt = np.asarray(nxt)
    assert ((nxt >= 0) & (nxt < bg.n)).all()
    assert ((np.asarray(hops) >= 1) & (np.asarray(hops) <= params.r)).all()


def test_bucketed_run_matches_sparse_run():
    """Whole trajectories (engine.run) agree bitwise between the sparse and
    bucketed layouts — the property that lets the stationary harness cover
    the bucketed path for free."""
    g = barabasi_albert(48, 3, seed=7, layout="dense")
    csr = g.to_csr()
    lips = np.exp(np.random.default_rng(2).normal(0, 0.5, g.n))
    params = MHLJParams(0.25, 0.5, 3)
    rp = jnp.asarray(row_probs_padded(mh_importance(g, lips), g))
    v0s = jnp.arange(24, dtype=jnp.int32) % csr.n
    key = jax.random.PRNGKey(3)
    n_sp, h_sp = _engine(csr, params, rp, "pallas", layout="sparse").run(
        key, v0s, 100
    )
    n_bk, h_bk = _engine(csr, params, rp, "pallas", layout="bucketed").run(
        key, v0s, 100
    )
    np.testing.assert_array_equal(np.asarray(n_sp), np.asarray(n_bk))
    np.testing.assert_array_equal(np.asarray(h_sp), np.asarray(h_bk))


# ---------------------------------------------------------------------------
# Per-step walk compaction (the fast bucketed dispatch)
# ---------------------------------------------------------------------------


def _parity_vs_sparse(csr, params, rp, nodes, key, **bucketed_kwargs):
    """Assert the bucketed engine (scan + pallas) matches layout='sparse'
    bitwise for this key/node set under the given compaction knobs."""
    ref_n, ref_h = _engine(csr, params, rp, "scan").step(key, nodes)
    for backend in ("scan", "pallas"):
        eng = WalkEngine.from_graph(
            csr, params, row_probs=rp, backend=backend, layout="bucketed",
            **bucketed_kwargs,
        )
        n2, h2 = eng.step(key, nodes)
        np.testing.assert_array_equal(np.asarray(ref_n), np.asarray(n2))
        np.testing.assert_array_equal(np.asarray(ref_h), np.asarray(h2))
        yield eng


def test_compacted_parity_w_not_block_multiple(setup):
    """Compacted dispatch at W values that are not block_w multiples (and
    bucket capacities that are not block multiples either) stays bitwise
    equal to layout='sparse' on the hub-heavy BA graph."""
    _, csr, _, params, rp = setup
    for w, block_w, seed in ((37, 16, 0), (300, 128, 1), (129, 64, 2)):
        key = jax.random.PRNGKey(seed)
        nodes = jnp.arange(w, dtype=jnp.int32) % csr.n
        for eng in _parity_vs_sparse(
            csr, params, rp, nodes, key, block_w=block_w, compact=True
        ):
            assert eng.compact


@pytest.mark.parametrize("bucket_factor", [2, 4])
def test_compacted_parity_bucket_factor(setup, bucket_factor):
    """Both width ladders (factor 2 and 4) sample identical walks."""
    _, csr, _, params, rp = setup
    key = jax.random.PRNGKey(5)
    nodes = jnp.arange(200, dtype=jnp.int32) % csr.n
    list(
        _parity_vs_sparse(
            csr, params, rp, nodes, key,
            compact=True, bucket_factor=bucket_factor,
        )
    )


def test_compacted_all_walks_in_one_bucket(setup):
    """Every walk on the same node: one bucket holds all W walks (its
    capacity clamps to W, the node-share rule would have given far less),
    every other bucket runs an all-slop pass — results still bitwise."""
    _, csr, _, params, rp = setup
    from repro.core import bucket_capacities, compact_plan

    nodes = jnp.full((160,), 5, jnp.int32)  # the trap node, all walks
    key = jax.random.PRNGKey(7)
    for eng in _parity_vs_sparse(csr, params, rp, nodes, key, compact=True):
        caps = bucket_capacities(160, eng.bucket_share, eng.capacity_factor)
        bid = eng.node_bucket[nodes]
        _, _, counts = compact_plan(bid, len(caps))
        counts = np.asarray(counts)
        occupied = np.nonzero(counts)[0]
        assert occupied.size == 1  # genuinely one bucket in play
        assert counts[occupied[0]] == 160
        # ... which means the step only stays compacted if that bucket's
        # capacity clamped up to W; otherwise the fallback ran — either
        # way parity held above.  Assert the empty buckets were real:
        assert (counts[counts == 0].size) == len(caps) - 1


def test_compacted_empty_bucket(setup):
    """Walks placed so at least one bucket is empty (count 0): its pass is
    all capacity slop and scatter_compacted must drop every lane."""
    _, csr, _, params, rp = setup
    from repro.core import compact_plan

    # walks only on low-degree nodes: hub buckets stay empty
    deg = np.asarray(csr.degrees)
    low = np.nonzero(deg <= np.median(deg))[0][:64]
    nodes = jnp.asarray(np.resize(low, 100), jnp.int32)
    key = jax.random.PRNGKey(11)
    for eng in _parity_vs_sparse(csr, params, rp, nodes, key, compact=True):
        _, _, counts = compact_plan(
            eng.node_bucket[nodes], len(eng.bucket_neighbors)
        )
        assert (np.asarray(counts) == 0).any()  # an empty bucket existed


def test_compacted_capacity_overflow_falls_back(setup):
    """A capacity_factor so small that counts exceed caps must trigger the
    uncompacted fallback — verified both by the plan arithmetic and by the
    step staying bitwise-identical to layout='sparse'."""
    _, csr, _, params, rp = setup
    from repro.core import bucket_capacities, compact_plan

    w = 300
    nodes = jnp.arange(w, dtype=jnp.int32) % csr.n
    key = jax.random.PRNGKey(13)
    engines = list(
        _parity_vs_sparse(
            csr, params, rp, nodes, key, compact=True, capacity_factor=1e-6
        )
    )
    eng = engines[0]
    # min_cap floors every capacity at 32 < the dominant bucket's count,
    # so this step overflowed and lax.cond took the full-dispatch branch
    caps = np.asarray(
        bucket_capacities(w, eng.bucket_share, eng.capacity_factor)
    )
    _, _, counts = compact_plan(eng.node_bucket[nodes], len(caps))
    assert (np.asarray(counts) > caps).any()


def test_compacted_run_matches_uncompacted_run(setup):
    """Whole trajectories: compaction changes the schedule of per-bucket
    work, never the sampled walk — engine.run agrees bitwise with both the
    uncompacted bucketed engine and the sparse layout."""
    _, csr, _, params, rp = setup
    v0s = jnp.arange(24, dtype=jnp.int32) % csr.n
    key = jax.random.PRNGKey(17)
    n_sp, h_sp = _engine(csr, params, rp, "pallas", layout="sparse").run(
        key, v0s, 60
    )
    for compact in (False, True):
        eng = WalkEngine.from_graph(
            csr, params, row_probs=rp, backend="pallas", layout="bucketed",
            compact=compact,
        )
        n_bk, h_bk = eng.run(key, v0s, 60)
        np.testing.assert_array_equal(np.asarray(n_sp), np.asarray(n_bk))
        np.testing.assert_array_equal(np.asarray(h_sp), np.asarray(h_bk))


def test_compacted_kernel_oracle_parity(setup):
    """The Pallas compacted dispatch and its ref oracle agree bitwise on
    hand-built compacted tiles, including dropped slop lanes."""
    from repro.core import bucket_capacities, compact_plan
    from repro.kernels.walk_transition.kernel import (
        walk_transition_bucketed_compacted,
    )
    from repro.kernels.walk_transition.ref import (
        walk_transition_bucketed_compacted_ref,
    )

    _, csr, _, params, rp = setup
    eng = WalkEngine.from_graph(
        csr, params, row_probs=rp, backend="scan", layout="bucketed"
    )
    w = 75
    nodes = jnp.arange(w, dtype=jnp.int32) % csr.n
    u_mh = jax.random.uniform(jax.random.PRNGKey(3), (w,))
    caps = bucket_capacities(w, eng.bucket_share, eng.capacity_factor)
    order, starts, counts = compact_plan(
        eng.node_bucket[nodes], len(caps)
    )
    # the engine's own gather convention — the same helper step() uses, so
    # this parity check cannot drift from the production gather
    widx_by, valid_by, rows_by, tiles_by, u_by = (
        eng.compacted_bucket_inputs(nodes, u_mh, caps, order, starts, counts)
    )
    got = walk_transition_bucketed_compacted(
        rows_by, tiles_by, u_by, widx_by, valid_by, w,
        block_w=16, interpret=True,
    )
    want = walk_transition_bucketed_compacted_ref(
        rows_by, tiles_by, u_by, widx_by, valid_by, w
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Ragged true-degree layout (flat per-edge CDF, no ladder)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "build",
    [
        lambda: barabasi_albert(80, 3, seed=3, layout="dense"),
        lambda: lollipop(16, 9),  # clique degree 16 sits on a bucket boundary
        lambda: dumbbell(6, 3),  # odd max_degree (7), no power-of-two help
    ],
)
def test_ragged_layout_bitwise_equal_all_paths(build):
    """layout='ragged' — the fused scalar-prefetch kernel AND its pure-jnp
    binary-search fallback — agrees bitwise with the sparse scan oracle,
    the sparse and dense Pallas layouts and the bucketed dispatch, at W
    values that are not block multiples, on hub-heavy (BA),
    bucket-boundary (lollipop) and odd-max-degree (dumbbell) graphs.  The
    ragged engines are driven once from the shared padded row table (exact
    flatten) and once from the flat numpy builder over a graph that never
    had a padded tensor."""
    g = build()
    csr = g.to_csr()
    rg = csr.to_ragged()
    lips = np.ones(g.n)
    lips[1] = 30.0
    params = MHLJParams(0.3, 0.5, 3)
    rp = jnp.asarray(row_probs_padded(mh_importance(g, lips), g))
    flat = mh_importance_rows_ragged(rg, lips)
    for w, block_w, key_seed in ((37, 16, 0), (300, 128, 1), (129, 64, 2)):
        key = jax.random.PRNGKey(key_seed)
        nodes = jnp.arange(w, dtype=jnp.int32) % csr.n
        ref_n, ref_h = _engine(csr, params, rp, "scan").step(key, nodes)
        candidates = [
            _engine(csr, params, rp, "pallas", layout="sparse",
                    block_w=block_w),
            _engine(csr, params, rp, "pallas", layout="dense",
                    block_w=block_w),
            _engine(csr, params, rp, "pallas", layout="bucketed",
                    block_w=block_w),
            _engine(csr, params, rp, "pallas", layout="ragged",
                    block_w=block_w),
            _engine(csr, params, rp, "scan", layout="ragged"),
            WalkEngine.from_graph(
                rg, params, row_probs=flat, backend="pallas",
                block_w=block_w,
            ),
            WalkEngine.from_graph(
                rg, params, row_probs=flat, backend="scan",
            ),
        ]
        for eng in candidates:
            n2, h2 = eng.step(key, nodes)
            np.testing.assert_array_equal(np.asarray(ref_n), np.asarray(n2))
            np.testing.assert_array_equal(np.asarray(ref_h), np.asarray(h2))


def test_ragged_rows_from_table_flat_builder_and_lipschitz_agree():
    """The three ragged row sources — shared padded table (exact flatten),
    flat numpy builder, live-lipschitz chunked build — produce engines
    whose flat CDFs invert to the identical walk per key (the builder
    chunks through the same block math at the same width, so this is
    bitwise, not approximate).  The numpy-builder source is additionally
    checked entry-for-entry against the padded numpy builder."""
    from repro.core import flat_edge_values, mh_importance_rows

    csr = barabasi_albert(90, 3, seed=9, layout="csr")
    rg = csr.to_ragged()
    lips = np.exp(np.random.default_rng(4).normal(0, 0.7, csr.n))
    params = MHLJParams(0.25, 0.5, 3)
    flat = mh_importance_rows_ragged(rg, lips)
    table = mh_importance_rows(csr, lips)
    np.testing.assert_array_equal(
        flat.view(np.int32),
        flat_edge_values(rg.indptr, rg.degrees, table).view(np.int32),
    )
    key = jax.random.PRNGKey(21)
    nodes = jnp.arange(70, dtype=jnp.int32) % csr.n
    engines = [
        WalkEngine.from_graph(
            rg, params, row_probs=flat, backend="scan"
        ),
        WalkEngine.from_graph(
            csr, params, row_probs=jnp.asarray(table), backend="scan",
            layout="ragged",
        ),
    ]
    results = [eng.step(key, nodes) for eng in engines]
    # live-lipschitz source matches the jnp sparse build it chunks through
    eng_live = WalkEngine.from_graph(
        csr, params, lipschitz=jnp.asarray(lips, jnp.float32),
        backend="scan", layout="ragged",
    )
    eng_live_sparse = WalkEngine.from_graph(
        csr, params, lipschitz=jnp.asarray(lips, jnp.float32),
        backend="scan", layout="sparse",
    )
    n_l, h_l = eng_live.step(key, nodes)
    n_s, h_s = eng_live_sparse.step(key, nodes)
    np.testing.assert_array_equal(np.asarray(n_l), np.asarray(n_s))
    np.testing.assert_array_equal(np.asarray(h_l), np.asarray(h_s))
    for n2, h2 in results[1:]:
        np.testing.assert_array_equal(
            np.asarray(results[0][0]), np.asarray(n2)
        )
        np.testing.assert_array_equal(
            np.asarray(results[0][1]), np.asarray(h2)
        )


def test_ragged_engine_resident_state_is_exactly_o_e():
    """The exactly-O(E) guarantee: a ragged engine carries no padded and
    no per-bucket table — every array leaf is one-dimensional with at most
    nnz + n + 1 entries — and a ``from_edges(layout='ragged')`` graph
    never holds a padded tensor at all.  Asking for full-width rows
    raises."""
    from repro.core import from_edges

    idx = np.arange(200, dtype=np.int64)
    graph = from_edges(
        200, idx, (idx + 1) % 200, name="ring-ragged", layout="ragged"
    )
    assert not hasattr(graph, "neighbors")  # the padded tensor never exists
    assert not hasattr(graph, "buckets")
    params = MHLJParams(0.2, 0.5, 3)
    lips = jnp.ones(graph.n)
    eng = WalkEngine.from_graph(graph, params, lipschitz=lips)
    assert eng.layout == "ragged"
    assert eng.neighbors is None and eng.row_probs is None
    assert eng.bucket_neighbors is None and eng.bucket_rows is None
    nnz, n = graph.num_edges, graph.n
    for leaf in jax.tree_util.tree_leaves(eng):
        assert jnp.ndim(leaf) <= 1  # nothing padded, nothing bucketed
        assert jnp.size(leaf) <= nnz + n + 1
    assert int(eng.edge_cdf.shape[0]) == nnz  # the O(E) row state, exactly
    with pytest.raises(ValueError, match="ragged layout"):
        eng.rows_table()
    with pytest.raises(ValueError, match="ragged layout"):
        eng.rows_for(jnp.arange(4, dtype=jnp.int32))
    # ragged precomputes its CDF at construction: a row-source-less build
    # fails loudly instead of deferring to a live path that cannot exist
    with pytest.raises(ValueError, match="precomputes its flat per-edge CDF"):
        WalkEngine.from_graph(graph, params, layout="ragged")
    nodes = jnp.arange(33, dtype=jnp.int32) % graph.n
    nxt, hops = eng.step(jax.random.PRNGKey(1), nodes)
    nxt = np.asarray(nxt)
    assert ((nxt >= 0) & (nxt < graph.n)).all()
    assert ((np.asarray(hops) >= 1) & (np.asarray(hops) <= params.r)).all()


def test_ragged_run_matches_sparse_run():
    """Whole trajectories (engine.run) agree bitwise between the sparse
    and ragged layouts — so the stationary/chi-square harness covers the
    ragged path exactly as it covers the others."""
    g = barabasi_albert(48, 3, seed=7, layout="dense")
    csr = g.to_csr()
    lips = np.exp(np.random.default_rng(2).normal(0, 0.5, g.n))
    params = MHLJParams(0.25, 0.5, 3)
    rp = jnp.asarray(row_probs_padded(mh_importance(g, lips), g))
    v0s = jnp.arange(24, dtype=jnp.int32) % csr.n
    key = jax.random.PRNGKey(3)
    n_sp, h_sp = _engine(csr, params, rp, "pallas", layout="sparse").run(
        key, v0s, 100
    )
    for backend in ("pallas", "scan"):
        n_rg, h_rg, aux = _engine(
            csr, params, rp, backend, layout="ragged"
        ).run(key, v0s, 100, with_aux=True)
        np.testing.assert_array_equal(np.asarray(n_sp), np.asarray(n_rg))
        np.testing.assert_array_equal(np.asarray(h_sp), np.asarray(h_rg))
        # no ladder -> no compaction -> the overflow telemetry is all-False
        assert not np.asarray(aux["compact_overflow"]).any()


def test_ragged_kernel_oracle_parity():
    """The fused scalar-prefetch kernel and its ref oracle agree bitwise
    on hand-built flat inputs, including W not a block multiple (padded
    kernel lanes sliced off)."""
    from repro.core import ragged_edge_cdf
    from repro.kernels.walk_transition.kernel import walk_transition_ragged
    from repro.kernels.walk_transition.ref import walk_transition_ragged_ref

    g = lollipop(12, 7)
    csr = g.to_csr()
    lips = np.ones(g.n)
    lips[2] = 20.0
    rp = row_probs_padded(mh_importance(g, lips), g)
    indptr = jnp.asarray(csr.indptr, jnp.int32)
    indices = jnp.asarray(csr.indices, jnp.int32)
    degrees = jnp.asarray(csr.degrees, jnp.int32)
    edge_cdf = ragged_edge_cdf(
        csr.indptr, csr.indices, csr.degrees, row_probs=rp
    )
    p_d, r = 0.5, 3
    w = 75  # not a multiple of block_w=16
    nodes = jnp.arange(w, dtype=jnp.int32) % csr.n
    u = jax.random.uniform(jax.random.PRNGKey(5), (w, 3 + r))
    u = u.at[:, 0].set((u[:, 0] < 0.3).astype(jnp.float32))
    got = walk_transition_ragged(
        nodes, indptr, degrees, indices, edge_cdf, u,
        p_d=p_d, r=r, max_degree=csr.max_degree, block_w=16, interpret=True,
    )
    want = walk_transition_ragged_ref(
        nodes, indptr, degrees, indices, edge_cdf, u,
        p_d=p_d, r=r, max_degree=csr.max_degree,
    )
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


def test_ragged_overflow_telemetry_surfaces_compaction_fallbacks():
    """step/run aux telemetry: a compacted bucketed engine with starved
    capacities reports compact_overflow=True (the step that lax.cond'ed to
    the full dispatch), a healthy one reports False — so the static
    capacity rule is auditable from production sweeps."""
    g = barabasi_albert(48, 3, seed=1, layout="dense")
    csr = g.to_csr()
    lips = np.ones(g.n)
    params = MHLJParams(0.25, 0.5, 3)
    rp = jnp.asarray(row_probs_padded(mh_importance(g, lips), g))
    nodes = jnp.arange(300, dtype=jnp.int32) % csr.n
    key = jax.random.PRNGKey(13)
    starved = WalkEngine.from_graph(
        csr, params, row_probs=rp, backend="scan", layout="bucketed",
        capacity_factor=1e-6,
    )
    _, _, aux = starved.step(key, nodes, with_aux=True)
    assert bool(aux["compact_overflow"])
    healthy = WalkEngine.from_graph(
        csr, params, row_probs=rp, backend="scan", layout="bucketed"
    )
    _, _, aux = healthy.step(key, nodes, with_aux=True)
    assert not bool(aux["compact_overflow"])
    # run() stacks the per-step flags
    _, _, aux = healthy.run(key, nodes[:16], 20, with_aux=True)
    assert np.asarray(aux["compact_overflow"]).shape == (20,)


def test_pure_csr_graph_end_to_end():
    """A graph that never had a dense form (from_edges csr layout) drives
    the engine: nodes stay in range and Remark-1 hops stay in [1, r]."""
    csr = sbm([40, 40, 40], 0.2, 0.01, seed=3, layout="csr")
    params = MHLJParams(0.3, 0.5, 4)
    rng = np.random.default_rng(0)
    lips = jnp.asarray(np.exp(rng.normal(0, 1, csr.n)), jnp.float32)
    eng = WalkEngine.from_graph(
        csr, params, lipschitz=lips, backend="scan", layout="sparse"
    )
    v0s = jnp.asarray(rng.integers(0, csr.n, 64), jnp.int32)
    nodes, hops = eng.run(jax.random.PRNGKey(9), v0s, 300)
    nodes, hops = np.asarray(nodes), np.asarray(hops)
    assert ((nodes >= 0) & (nodes < csr.n)).all()
    assert ((hops >= 1) & (hops <= params.r)).all()
