"""Sparse-layout engine correctness — the acceptance contract of the CSR
refactor.

Three claims:

1. The sparse scan backend, the sparse Pallas tile backend, and the dense
   ``mhlj()`` matrix chain realize the SAME transition law on an irregular
   (CSR-built) graph — chi-square at ~4-sigma.
2. Scan and sparse-Pallas are BITWISE equal given the same key, including
   when ``max_degree`` is odd (not a multiple of any block/lane size) and
   W is not a multiple of ``block_w``.
3. The sparse layout is genuinely O(E): the full (n, max_deg) row table is
   never materialized on the live-rows path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MHLJParams,
    WalkEngine,
    barabasi_albert,
    dumbbell,
    mh_importance,
    mhlj,
    row_probs_padded,
    sbm,
)


@pytest.fixture(scope="module")
def setup():
    # irregular hub-heavy graph, built dense for the matrix-chain oracle;
    # the engine consumes its O(E) CSR twin
    g = barabasi_albert(48, 3, seed=1, layout="dense")
    csr = g.to_csr()
    lips = np.ones(g.n)
    lips[5] = 35.0  # trap node
    params = MHLJParams(0.25, 0.5, 3)
    rp = jnp.asarray(row_probs_padded(mh_importance(g, lips), g))
    return g, csr, lips, params, rp


def _engine(csr, params, rp, backend, layout="sparse", block_w=256):
    return WalkEngine.from_graph(
        csr, params, row_probs=rp, backend=backend, layout=layout,
        block_w=block_w,
    )


def _chi_square_stat(counts, probs, min_expected=10.0):
    """Pearson chi-square with small-expectation bins lumped together."""
    total = counts.sum()
    expected = probs * total
    big = expected >= min_expected
    obs = np.concatenate([counts[big], [counts[~big].sum()]])
    exp = np.concatenate([expected[big], [expected[~big].sum()]])
    keep = exp > 0
    obs, exp = obs[keep], exp[keep]
    stat = float(((obs - exp) ** 2 / exp).sum())
    return stat, len(obs) - 1


def test_sparse_backends_bitwise_equal_odd_max_degree(setup):
    """Scan and sparse-Pallas tiles agree bitwise on a CSR graph whose
    max_degree (7) is not a multiple of any block size, across W values
    that are not block multiples either."""
    _, _, _, params, _ = setup
    g = dumbbell(6, 3)  # clique bridge node: deg 7 — odd on purpose
    assert g.max_degree % 2 == 1
    csr = g.to_csr()
    lips = np.ones(g.n)
    lips[0] = 25.0
    rp = jnp.asarray(row_probs_padded(mh_importance(g, lips), g))
    key = jax.random.PRNGKey(0)
    for w, block_w in ((128, 64), (300, 128), (37, 256), (5, 4)):
        nodes = jnp.arange(w, dtype=jnp.int32) % csr.n
        n_s, h_s = _engine(csr, params, rp, "scan").step(key, nodes)
        n_p, h_p = _engine(
            csr, params, rp, "pallas", block_w=block_w
        ).step(key, nodes)
        np.testing.assert_array_equal(np.asarray(n_s), np.asarray(n_p))
        np.testing.assert_array_equal(np.asarray(h_s), np.asarray(h_p))


def test_sparse_and_dense_layouts_bitwise_equal(setup):
    """The sparse tile kernel and the legacy full-table kernel are the same
    transition, bit for bit."""
    _, csr, _, params, rp = setup
    key = jax.random.PRNGKey(2)
    nodes = jnp.arange(200, dtype=jnp.int32) % csr.n
    n_sp, h_sp = _engine(csr, params, rp, "pallas", layout="sparse").step(key, nodes)
    n_dn, h_dn = _engine(csr, params, rp, "pallas", layout="dense").step(key, nodes)
    np.testing.assert_array_equal(np.asarray(n_sp), np.asarray(n_dn))
    np.testing.assert_array_equal(np.asarray(h_sp), np.asarray(h_dn))


@pytest.mark.slow
def test_sparse_backends_match_dense_chain_chi_square(setup):
    """Empirical one-step law of the sparse scan backend AND the sparse
    Pallas backend vs the dense MHLJ matrix chain, chi-square at ~4-sigma,
    on the irregular BA graph."""
    g, csr, lips, params, rp = setup
    start = 5
    w = 30_000
    nodes = jnp.full((w,), start, jnp.int32)
    expected_row = mhlj(g, lips, params)[start]  # chained-Levy exact law

    for backend, key in (("scan", 11), ("pallas", 12)):
        nxt, _ = _engine(csr, params, rp, backend).step(
            jax.random.PRNGKey(key), nodes
        )
        counts = np.bincount(np.asarray(nxt), minlength=csr.n).astype(np.float64)
        stat, dof = _chi_square_stat(counts, expected_row)
        crit = dof + 4.0 * np.sqrt(2.0 * dof)
        assert stat < crit, f"{backend}: chi2={stat:.1f} >= {crit:.1f} (dof={dof})"


def test_sparse_layout_never_builds_full_table(setup, monkeypatch):
    """O(E) guarantee: with live Eq.-7 rows, neither sparse backend ever
    calls ``rows_table`` (the dense layout does — sanity-checked last)."""
    _, csr, lips, params, _ = setup
    lips_j = jnp.asarray(lips, jnp.float32)
    nodes = jnp.arange(32, dtype=jnp.int32) % csr.n

    def boom(self, lipschitz=None):
        raise AssertionError("sparse layout materialized the dense row table")

    monkeypatch.setattr(WalkEngine, "rows_table", boom)
    for backend in ("scan", "pallas"):
        eng = WalkEngine.from_graph(
            csr, params, backend=backend, layout="sparse"
        )
        nxt, hops = eng.step(jax.random.PRNGKey(3), nodes, lipschitz=lips_j)
        nxt = np.asarray(nxt)
        assert ((nxt >= 0) & (nxt < csr.n)).all()

    monkeypatch.undo()
    called = {}
    real = WalkEngine.rows_table

    def spying(self, lipschitz=None):
        called["yes"] = True
        return real(self, lipschitz)

    monkeypatch.setattr(WalkEngine, "rows_table", spying)
    eng = WalkEngine.from_graph(csr, params, backend="pallas", layout="dense")
    eng.step(jax.random.PRNGKey(4), nodes, lipschitz=lips_j)
    assert called.get("yes")


def test_pure_csr_graph_end_to_end():
    """A graph that never had a dense form (from_edges csr layout) drives
    the engine: nodes stay in range and Remark-1 hops stay in [1, r]."""
    csr = sbm([40, 40, 40], 0.2, 0.01, seed=3, layout="csr")
    params = MHLJParams(0.3, 0.5, 4)
    rng = np.random.default_rng(0)
    lips = jnp.asarray(np.exp(rng.normal(0, 1, csr.n)), jnp.float32)
    eng = WalkEngine.from_graph(
        csr, params, lipschitz=lips, backend="scan", layout="sparse"
    )
    v0s = jnp.asarray(rng.integers(0, csr.n, 64), jnp.int32)
    nodes, hops = eng.run(jax.random.PRNGKey(9), v0s, 300)
    nodes, hops = np.asarray(nodes), np.asarray(hops)
    assert ((nodes >= 0) & (nodes < csr.n)).all()
    assert ((hops >= 1) & (hops <= params.r)).all()
