"""Empirical vs theoretical stationary distributions (total variation).

Long-run occupancy of the simulated chains must match the theoretical
``pi`` of the corresponding transition matrix:

* simple RW:  pi(v) ∝ deg(v)                (closed form, reversible)
* MH-uniform: pi = uniform                  (MH construction target)
* MHLJ:       pi = left Perron vector of the dense ``mhlj()`` chain
              (the chained-Levy exact law of Algorithm 1)

Walks start from exact ``pi`` draws, so the chains are stationary from
t=0 and the only error is (correlated) sampling noise; tolerances leave
~3x headroom over the observed TV at these sample sizes.  Graphs cover
the paper's topologies and the new trap-prone families (ring, star, SBM
bottleneck, dumbbell).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MHLJParams,
    dumbbell,
    mhlj,
    mixing,
    ring,
    sbm,
    simple_rw,
    star,
    simple_rw_rows,
    mh_uniform_rows,
    walk_markov_batched,
    walk_mhlj_batched,
    row_probs_padded,
    mh_importance,
)
from repro.core.walk import empirical_distribution, graph_tensors

pytestmark = pytest.mark.slow

NUM_WALKS = 256
NUM_STEPS = 800
TV_TOL = 0.08


def _graphs():
    return {
        "ring": ring(24),
        "star": star(16),
        "sbm": sbm([12, 12], 0.6, 0.06, seed=1),
        "dumbbell": dumbbell(8, 4),
    }


def _pi_starts(pi, num_walks, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.choice(pi.size, size=num_walks, p=pi), jnp.int32)


def _occupancy_markov(g, rows, pi, seed):
    nbrs, _ = graph_tensors(g)
    v0s = _pi_starts(pi, NUM_WALKS, seed)
    traj = walk_markov_batched(
        jax.random.PRNGKey(seed), jnp.asarray(rows), nbrs, v0s, NUM_STEPS
    )
    return empirical_distribution(np.asarray(traj), g.n)


@pytest.mark.parametrize("tag", ["ring", "star", "sbm", "dumbbell"])
def test_simple_rw_occupancy_matches_degree_pi(tag):
    g = _graphs()[tag]
    pi = np.asarray(g.degrees, np.float64)
    pi /= pi.sum()
    emp = _occupancy_markov(g, simple_rw_rows(g), pi, seed=10)
    tv = mixing.tv_distance(emp, pi)
    assert tv < TV_TOL, f"{tag}: TV(emp, deg-pi)={tv:.3f}"
    # closed form agrees with the dense chain's Perron vector
    pi_dense = mixing.stationary_distribution(simple_rw(g))
    assert mixing.tv_distance(pi, pi_dense) < 1e-8


@pytest.mark.parametrize("tag", ["ring", "star", "sbm", "dumbbell"])
def test_mh_uniform_occupancy_is_uniform(tag):
    g = _graphs()[tag]
    pi = np.full(g.n, 1.0 / g.n)
    emp = _occupancy_markov(g, mh_uniform_rows(g), pi, seed=11)
    tv = mixing.tv_distance(emp, pi)
    assert tv < TV_TOL, f"{tag}: TV(emp, uniform)={tv:.3f}"


@pytest.mark.parametrize("tag", ["ring", "star", "sbm", "dumbbell"])
def test_mhlj_update_occupancy_matches_chain_pi(tag):
    """The engine's update-node sequence is stationary for the dense
    chained-Levy MHLJ matrix — on every trap-prone family."""
    g = _graphs()[tag]
    rng = np.random.default_rng(42)
    lips = np.exp(rng.normal(0.0, 0.8, g.n))
    params = MHLJParams(0.2, 0.5, 3)
    pi = mixing.stationary_distribution(mhlj(g, lips, params))
    rp = jnp.asarray(row_probs_padded(mh_importance(g, lips), g))
    nbrs, degs = graph_tensors(g)
    v0s = _pi_starts(pi, NUM_WALKS, seed=12)
    update_nodes, _ = walk_mhlj_batched(
        jax.random.PRNGKey(12), rp, nbrs, degs, v0s, NUM_STEPS,
        params.p_j, params.p_d, params.r, backend="scan",
    )
    emp = empirical_distribution(np.asarray(update_nodes), g.n)
    tv = mixing.tv_distance(emp, pi)
    assert tv < TV_TOL, f"{tag}: TV(emp, mhlj-pi)={tv:.3f}"


def test_occupancy_test_has_power():
    """Sanity: on the star graph the simple-RW occupancy is FAR from
    uniform (hub pi ~ 1/2), so the TV tolerance above is discriminative."""
    g = star(16)
    pi_deg = np.asarray(g.degrees, np.float64)
    pi_deg /= pi_deg.sum()
    emp = _occupancy_markov(g, simple_rw_rows(g), pi_deg, seed=13)
    uniform = np.full(g.n, 1.0 / g.n)
    assert mixing.tv_distance(emp, uniform) > 3 * TV_TOL
