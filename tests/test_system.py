"""End-to-end system tests: training driver, checkpoint/resume, multi-walk,
serving engine, and the Remark-1 accounting on the LLM path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_arch, reduced
from repro.core.graphs import ring
from repro.core.levy import remark1_bound
from repro.core.transition import MHLJParams
from repro.launch.serve import Request, ServeEngine
from repro.launch.train import run_training
from repro.models.factory import build_model
from repro.utils import checkpoint as ckpt
from repro.walk_sgd.llm_trainer import WalkContext, init_walk_state
from repro.walk_sgd.multi_walk import (
    average_params,
    init_multi_walk_state,
    make_multi_walk_step,
    stack_params,
)


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduced(get_arch("qwen2.5-32b"))


def test_train_driver_loss_decreases(tiny_cfg):
    # 120 steps + a 20-step tail window: per-silo loss heterogeneity makes
    # shorter windows sensitive to the walk's sample path (the unified
    # engine draws a different — equally lawful — stream than the seed code)
    res = run_training(
        tiny_cfg, graph_kind="ring", n_silos=8, method="mhlj", steps=120,
        batch_size=2, seq_len=64, lr=1e-3, log_every=0, seed=0,
    )
    assert res["losses"][-20:].mean() < res["losses"][:10].mean() - 0.3
    assert np.isfinite(res["losses"]).all()
    # online Lipschitz estimates became node-specific
    assert np.unique(res["final_lipschitz"]).size > 1


def test_train_driver_remark1_accounting(tiny_cfg):
    p_j, p_d, r = 0.3, 0.5, 3
    res = run_training(
        tiny_cfg, graph_kind="ring", n_silos=8, method="mhlj", steps=120,
        batch_size=2, seq_len=32, p_j=p_j, p_d=p_d, r=r, log_every=0, seed=1,
    )
    assert 1.0 <= res["transitions_per_update"] <= remark1_bound(p_j, p_d, r) + 0.2


def test_checkpoint_roundtrip_and_resume(tiny_cfg, tmp_path):
    root = str(tmp_path / "ckpt")
    model = build_model(tiny_cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    optimizer = optim.adamw(1e-3)
    opt_state = optimizer.init(params)
    walk_state = init_walk_state(8, np.ones(8, np.float32), seed=3)

    ckpt.save_checkpoint(root, 10, params, opt_state, walk_state, extra={"a": 1})
    ckpt.save_checkpoint(root, 20, params, opt_state, walk_state)
    assert ckpt.latest_step(root) == 20

    out = ckpt.load_checkpoint(root, params, opt_state, walk_state, step=10)
    assert out["step"] == 10 and out["extra"] == {"a": 1}
    for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(out["params"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # walk state resumes the same trajectory (node + rng restored exactly)
    for a, b in zip(
        jax.tree_util.tree_leaves(walk_state),
        jax.tree_util.tree_leaves(out["walk_state"]),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention(tmp_path):
    root = str(tmp_path / "ckpt")
    tree = {"w": jnp.ones((3,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(root, s, tree, keep=2)
    assert ckpt.latest_step(root) == 5
    with pytest.raises(FileNotFoundError):
        ckpt.load_pytree(f"{root}/step_0000000001/params.npz", tree)


def test_multi_walk_step_and_averaging(tiny_cfg):
    W = 3
    model = build_model(tiny_cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    optimizer = optim.sgd(1e-2)
    graph = ring(8)
    walk = WalkContext.from_graph(graph, MHLJParams(0.2, 0.5, 3))

    params_w = stack_params(params, W)
    opt_w = jax.vmap(optimizer.init)(params_w)
    walk_w = init_multi_walk_state(8, W, np.ones(8, np.float32), v0s=[0, 3, 6])
    step = jax.jit(make_multi_walk_step(model, optimizer, walk, avg_every=2))

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 64, (W, 2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 64, (W, 2, 32)), jnp.int32),
    }
    # step 0: no averaging -> replicas diverge (different walk nodes/weights)
    params_w, opt_w, walk_w, m = step(params_w, opt_w, walk_w, batch, jnp.asarray(0))
    assert m["loss"].shape == (W,)
    lead = jax.tree_util.tree_leaves(params_w)[0]
    assert float(jnp.abs(lead[0] - lead[1]).max()) > 0
    # step 1: avg_every=2 -> all replicas identical afterwards
    params_w, opt_w, walk_w, m = step(params_w, opt_w, walk_w, batch, jnp.asarray(1))
    for leaf in jax.tree_util.tree_leaves(params_w):
        np.testing.assert_allclose(
            np.asarray(leaf[0]), np.asarray(leaf[1]), rtol=0, atol=0
        )
    # averaging is itself idempotent
    avg = average_params(params_w)
    for a, b in zip(jax.tree_util.tree_leaves(avg), jax.tree_util.tree_leaves(params_w)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


@pytest.mark.parametrize("arch", ["mamba2-370m", "olmoe-1b-7b"])
def test_serve_engine_completes(arch):
    cfg = reduced(get_arch(arch))
    engine = ServeEngine(cfg, batch_size=2, cache_len=128)
    rng = np.random.default_rng(0)
    for rid in range(4):
        engine.submit(
            Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                max_new_tokens=5,
            )
        )
    stats = engine.run()
    assert stats["completed"] == 4
    assert stats["generated_tokens"] == 20
    assert 0 < stats["slot_utilization"] <= 1.0
    for req in engine.completed:
        assert all(0 <= t < cfg.vocab_size for t in req.generated)


def test_uniform_vs_mhlj_methods_run(tiny_cfg):
    """All three --method paths execute and produce finite losses."""
    for method in ("uniform", "importance", "mhlj"):
        res = run_training(
            tiny_cfg, graph_kind="expander", n_silos=8, method=method, steps=10,
            batch_size=2, seq_len=32, log_every=0, seed=2,
        )
        assert np.isfinite(res["losses"]).all()


def test_resume_is_bitwise_deterministic(tiny_cfg, tmp_path):
    """A job killed at step 20 and resumed reproduces the uninterrupted
    40-step run exactly: same walk trajectory, same batches, same losses."""
    kw = dict(
        graph_kind="ring", n_silos=8, method="mhlj", steps=40,
        batch_size=2, seq_len=32, lr=1e-3, log_every=0, seed=9,
    )
    full = run_training(tiny_cfg, **kw)

    root = str(tmp_path / "resume_ckpt")
    part = dict(kw)
    part["steps"] = 20
    run_training(
        tiny_cfg, **part, checkpoint_dir=root, checkpoint_every=20,
    )
    resumed = run_training(
        tiny_cfg, **kw, checkpoint_dir=root, checkpoint_every=20, resume=True,
    )
    # resumed run covers steps 20..40; compare against the full run's tail
    np.testing.assert_array_equal(resumed["update_nodes"], full["update_nodes"][20:])
    np.testing.assert_allclose(resumed["losses"], full["losses"][20:], rtol=1e-6)
    for a, b in zip(
        jax.tree_util.tree_leaves(resumed["params"]),
        jax.tree_util.tree_leaves(full["params"]),
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
