"""Transition-matrix invariants (paper Eqs. 6-8) + hypothesis properties.

Only the property-based tests need hypothesis (a dev-only dependency,
requirements-dev.txt); the deterministic invariants below must run even
where it is absent — a module-level importorskip silently disabled ALL of
them on bare installs.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare installs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="dev-only dependency (requirements-dev.txt)",
)

from repro.core import (
    MHLJParams,
    erdos_renyi,
    grid2d,
    levy_matrix,
    levy_matrix_chained,
    mh_importance,
    mh_uniform,
    mhlj,
    ring,
    simple_rw,
    trunc_geom_pmf,
)
from repro.core import mixing
from repro.core.transition import is_row_stochastic, row_probs_padded, supported_on_graph


def _rand_lipschitz(n, seed=0, spread=100.0):
    rng = np.random.default_rng(seed)
    lips = rng.uniform(1.0, 2.0, n)
    lips[rng.integers(0, n)] *= spread
    return lips


@pytest.mark.parametrize("graph", [ring(12), grid2d(4, 4), erdos_renyi(15, 0.4)])
def test_all_designs_row_stochastic_and_supported(graph):
    lips = _rand_lipschitz(graph.n)
    for p in (simple_rw(graph), mh_uniform(graph), mh_importance(graph, lips)):
        assert is_row_stochastic(p)
        assert supported_on_graph(p, graph)
    p = mhlj(graph, lips, MHLJParams(0.2, 0.5, 3))
    assert is_row_stochastic(p)  # r-hop kernel: not 1-hop supported, by design


def test_mh_uniform_stationary_is_uniform(small_ring):
    pi = mixing.stationary_distribution(mh_uniform(small_ring))
    np.testing.assert_allclose(pi, np.full(small_ring.n, 1 / small_ring.n), atol=1e-9)


def test_mh_importance_stationary_is_pi_is(small_ring, hetero_lipschitz):
    pi = mixing.stationary_distribution(mh_importance(small_ring, hetero_lipschitz))
    np.testing.assert_allclose(
        pi, hetero_lipschitz / hetero_lipschitz.sum(), atol=1e-9
    )


def test_simple_rw_stationary_proportional_to_degree(small_ring):
    pi = mixing.stationary_distribution(simple_rw(small_ring))
    deg = small_ring.degrees.astype(float)
    np.testing.assert_allclose(pi, deg / deg.sum(), atol=1e-9)


def test_detailed_balance_eq8(small_ring, hetero_lipschitz):
    """Paper Eq. (8): L_i / L_j = P_IS(j,i) / P_IS(i,j) on edges."""
    p = mh_importance(small_ring, hetero_lipschitz)
    for i in range(small_ring.n):
        for j in range(small_ring.n):
            if i != j and small_ring.adj[i, j] and p[i, j] > 0:
                np.testing.assert_allclose(
                    hetero_lipschitz[i] / hetero_lipschitz[j],
                    p[j, i] / p[i, j],
                    rtol=1e-8,
                )


def test_mh_is_reversible_mhlj_is_not(small_ring, hetero_lipschitz, mhlj_params):
    p_is = mh_importance(small_ring, hetero_lipschitz)
    assert mixing.is_reversible(p_is)
    p = mhlj(small_ring, hetero_lipschitz, mhlj_params)
    assert not mixing.is_reversible(p)  # jumps break detailed balance (paper §V)


def test_levy_matrix_forms_agree_on_regular_graph(small_ring):
    """Adjacency-power and chained-hop forms coincide on regular graphs."""
    a = levy_matrix(small_ring, 0.5, 3)
    b = levy_matrix_chained(small_ring, 0.5, 3)
    np.testing.assert_allclose(a, b, atol=1e-12)


def test_levy_matrix_forms_differ_on_irregular_graph():
    from repro.core import star

    g = star(8)
    a = levy_matrix(g, 0.5, 3)
    b = levy_matrix_chained(g, 0.5, 3)
    assert np.abs(a - b).max() > 1e-3  # documented discrepancy (levy.py docstring)


def test_mhlj_is_mixture(small_ring, hetero_lipschitz, mhlj_params):
    p = mhlj(small_ring, hetero_lipschitz, mhlj_params)
    p_is = mh_importance(small_ring, hetero_lipschitz)
    p_levy = levy_matrix_chained(small_ring, mhlj_params.p_d, mhlj_params.r)
    np.testing.assert_allclose(
        p, (1 - mhlj_params.p_j) * p_is + mhlj_params.p_j * p_levy, atol=1e-12
    )


if HAVE_HYPOTHESIS:

    @needs_hypothesis
    @given(
        p_d=st.floats(0.05, 0.95),
        r=st.integers(1, 8),
    )
    @settings(max_examples=40, deadline=None)
    def test_trunc_geom_pmf_properties(p_d, r):
        pmf = trunc_geom_pmf(p_d, r)
        assert pmf.shape == (r,)
        assert abs(pmf.sum() - 1.0) < 1e-9
        assert np.all(np.diff(pmf) <= 1e-12)  # monotone decreasing

    @needs_hypothesis
    @given(
        n=st.integers(5, 24),
        p_j=st.floats(0.0, 0.9),
        seed=st.integers(0, 10),
    )
    @settings(max_examples=25, deadline=None)
    def test_mhlj_row_stochastic_property(n, p_j, seed):
        g = erdos_renyi(n, 0.4, seed=seed)
        lips = _rand_lipschitz(n, seed)
        p = mhlj(g, lips, MHLJParams(p_j, 0.5, 3))
        assert is_row_stochastic(p)
        pi = mixing.stationary_distribution(p)
        assert np.all(pi > 0) and abs(pi.sum() - 1) < 1e-8


def test_row_probs_padded_matches_dense(small_ring, hetero_lipschitz):
    p = mh_importance(small_ring, hetero_lipschitz)
    padded = row_probs_padded(p, small_ring)
    for v in range(small_ring.n):
        dense_row = np.zeros(small_ring.n)
        deg = small_ring.degrees[v]
        for slot in range(deg):
            dense_row[small_ring.neighbors[v, slot]] += padded[v, slot]
        np.testing.assert_allclose(dense_row, p[v], atol=1e-6)


# ---------------------------------------------------------------------------
# mh() proposal validation — regression for the silent-repair bug
# ---------------------------------------------------------------------------


def test_mh_rejects_non_stochastic_proposal(small_ring):
    """Pre-fix, mh() renormalized a non-row-stochastic q and returned a
    chain targeting the WRONG stationary distribution without a word."""
    from repro.core import mh

    pi = np.full(small_ring.n, 1.0 / small_ring.n)
    q = simple_rw(small_ring)
    q[0] *= 0.5  # row 0 now sums to 0.5
    with pytest.raises(ValueError, match="not row-stochastic"):
        mh(small_ring, pi, q=q)


def test_mh_rejects_off_graph_proposal(small_ring):
    """Pre-fix, off-graph proposal mass was masked away — the resulting
    chain was not the MH chain of q and its pi was silently wrong."""
    from repro.core import mh

    n = small_ring.n
    pi = np.full(n, 1.0 / n)
    q = np.full((n, n), 1.0 / n)  # complete-graph proposal: mass on non-edges
    assert not supported_on_graph(q, small_ring)
    with pytest.raises(ValueError, match="non-edges"):
        mh(small_ring, pi, q=q)


def test_mh_rejects_wrong_shape_proposal(small_ring):
    from repro.core import mh

    pi = np.full(small_ring.n, 1.0 / small_ring.n)
    with pytest.raises(ValueError, match="shape"):
        mh(small_ring, pi, q=np.eye(small_ring.n + 1))


def test_mh_accepts_valid_custom_proposal(small_ring):
    """A lazy (self-loop-holding) valid proposal passes validation and its
    MH chain still targets pi — validation must not reject good input."""
    from repro.core import mh

    rng = np.random.default_rng(0)
    pi = rng.uniform(0.5, 2.0, small_ring.n)
    pi /= pi.sum()
    q = 0.5 * simple_rw(small_ring) + 0.5 * np.eye(small_ring.n)
    assert is_row_stochastic(q) and supported_on_graph(q, small_ring)
    p = mh(small_ring, pi, q=q)
    assert is_row_stochastic(p)
    np.testing.assert_allclose(
        mixing.stationary_distribution(p), pi, atol=1e-9
    )


# ---------------------------------------------------------------------------
# New chain laws: dense invariants
# ---------------------------------------------------------------------------


def test_heterogeneity_mh_targets_pi(small_ring):
    from repro.core import heterogeneity_mh

    rng = np.random.default_rng(1)
    pi = rng.uniform(0.5, 3.0, small_ring.n)
    pi /= pi.sum()
    p = heterogeneity_mh(small_ring, pi)
    assert is_row_stochastic(p)
    assert supported_on_graph(p, small_ring)
    np.testing.assert_allclose(
        mixing.stationary_distribution(p), pi, atol=1e-9
    )


def test_heterogeneity_mh_rejects_bad_targets(small_ring):
    from repro.core import heterogeneity_mh

    with pytest.raises(ValueError, match="shape"):
        heterogeneity_mh(small_ring, np.ones(small_ring.n + 2))
    bad = np.full(small_ring.n, 1.0 / small_ring.n)
    bad[3] = 0.0
    with pytest.raises(ValueError, match="positive"):
        heterogeneity_mh(small_ring, bad)


def test_private_weighted_mh_targets_noised_weights(small_ring):
    """Stationary law of the private chain is ŵ/Σŵ — the NOISED weights,
    not the true ones: that gap is the privacy mechanism."""
    from repro.core import private_weighted_mh, private_weights

    rng = np.random.default_rng(2)
    w = np.exp(rng.normal(0.0, 0.6, small_ring.n))
    gamma, seed = 0.8, 3
    p = private_weighted_mh(small_ring, w, gamma, seed=seed)
    assert is_row_stochastic(p)
    assert supported_on_graph(p, small_ring)
    w_hat = private_weights(w, gamma, seed=seed)
    np.testing.assert_allclose(
        mixing.stationary_distribution(p), w_hat / w_hat.sum(), atol=1e-9
    )
    # ... and it genuinely differs from the non-private chain's target
    assert mixing.tv_distance(w_hat / w_hat.sum(), w / w.sum()) > 1e-4
