"""Walk simulator correctness: empirical laws match analytic chains."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MHLJParams,
    expected_transitions_per_update,
    mh_importance,
    mh_uniform,
    mhlj,
    remark1_bound,
    ring,
    row_probs_padded,
)
from repro.core import mixing, schedules
from repro.core.walk import (
    empirical_distribution,
    graph_tensors,
    walk_markov,
    walk_markov_batched,
    walk_mhlj,
)


@pytest.fixture(scope="module")
def setup():
    g = ring(16)
    lips = np.ones(16)
    lips[3] = 50.0
    p_is = mh_importance(g, lips)
    nbrs, degs = graph_tensors(g)
    rp = jnp.asarray(row_probs_padded(p_is, g))
    return g, lips, p_is, nbrs, degs, rp


def test_markov_walk_reaches_stationary(setup):
    g, lips, p_is, nbrs, degs, rp = setup
    p_uni = mh_uniform(g)
    rp_uni = jnp.asarray(row_probs_padded(p_uni, g))
    traj = walk_markov(jax.random.PRNGKey(0), rp_uni, nbrs, 0, 40_000)
    emp = empirical_distribution(np.asarray(traj), g.n, burn_in=4_000)
    assert 0.5 * np.abs(emp - 1.0 / g.n).sum() < 0.05


def test_is_walk_occupancy_matches_pi_is(setup):
    g, lips, p_is, nbrs, degs, rp = setup
    traj = walk_markov(jax.random.PRNGKey(1), rp, nbrs, 0, 60_000)
    emp = empirical_distribution(np.asarray(traj), g.n, burn_in=6_000)
    pi = lips / lips.sum()
    assert 0.5 * np.abs(emp - pi).sum() < 0.08


def test_mhlj_walk_matches_analytic_mixture(setup):
    g, lips, p_is, nbrs, degs, rp = setup
    params = MHLJParams(0.1, 0.5, 3)
    nodes, _ = walk_mhlj(
        jax.random.PRNGKey(2), rp, nbrs, degs, 0, 60_000, params.p_j, params.p_d, params.r
    )
    emp = empirical_distribution(np.asarray(nodes), g.n, burn_in=6_000)
    pi = mixing.stationary_distribution(mhlj(g, lips, params))
    assert 0.5 * np.abs(emp - pi).sum() < 0.08


def test_remark1_transition_accounting(setup):
    g, lips, p_is, nbrs, degs, rp = setup
    p_j, p_d, r = 0.1, 0.5, 3
    _, hops = walk_mhlj(jax.random.PRNGKey(3), rp, nbrs, degs, 0, 50_000, p_j, p_d, r)
    measured = float(np.asarray(hops, dtype=np.float64).mean())
    exact = expected_transitions_per_update(p_j, p_d, r)
    bound = remark1_bound(p_j, p_d, r)
    assert abs(measured - exact) < 0.02
    assert measured <= bound + 0.02
    assert exact <= bound + 1e-12


def test_pj_zero_schedule_recovers_pure_mh(setup):
    """With p_J=0 the MHLJ walk law equals the MH-IS walk law."""
    g, lips, p_is, nbrs, degs, rp = setup
    nodes, hops = walk_mhlj(jax.random.PRNGKey(4), rp, nbrs, degs, 0, 30_000, 0.0, 0.5, 3)
    assert int(np.asarray(hops).max()) == 1  # never jumps
    emp = empirical_distribution(np.asarray(nodes), g.n, burn_in=3_000)
    pi = lips / lips.sum()
    assert 0.5 * np.abs(emp - pi).sum() < 0.1


def test_batched_walks_shapes(setup):
    g, lips, p_is, nbrs, degs, rp = setup
    v0s = jnp.arange(8, dtype=jnp.int32)
    trajs = walk_markov_batched(jax.random.PRNGKey(5), rp, nbrs, v0s, 100)
    assert trajs.shape == (8, 101)
    assert bool((trajs[:, 0] == v0s).all())


def test_annealed_schedule_walk(setup):
    g, lips, p_is, nbrs, degs, rp = setup
    # t0=500 keeps p_J ~ 0.3 over the early window, ~0.027 at the tail
    sched = jnp.asarray(schedules.polynomial_decay(0.3, 5_000, t0=500))
    nodes, hops = walk_mhlj(jax.random.PRNGKey(6), rp, nbrs, degs, 0, 5_000, sched, 0.5, 3)
    # early phase jumps (mean hops ~ 1 + 0.3*(E[d]-1) ~ 1.21), late nearly never
    early = float(np.asarray(hops[:500], dtype=np.float64).mean())
    late = float(np.asarray(hops[-500:], dtype=np.float64).mean())
    assert early > late
    assert early > 1.08
    assert late < 1.05


# ---------------------------------------------------------------------------
# Schedule factory validation — satellite regressions
# ---------------------------------------------------------------------------


def test_step_decay_rejects_nonpositive_drop_every():
    """Pre-fix, drop_every=0 crashed with a bare ZeroDivisionError and a
    negative value silently produced a GROWING p_J staircase."""
    with pytest.raises(ValueError, match="drop_every"):
        schedules.step_decay(0.3, 100, drop_every=0)
    with pytest.raises(ValueError, match="drop_every"):
        schedules.step_decay(0.3, 100, drop_every=-5)


@pytest.mark.parametrize("bad_pj0", [-0.1, 1.5])
def test_all_schedules_reject_out_of_range_pj0(bad_pj0):
    """Pre-fix no factory checked p_j0: an out-of-range value fed the
    engine a Bernoulli parameter outside [0, 1]."""
    with pytest.raises(ValueError, match="p_j0|p_j"):
        schedules.constant(bad_pj0, 100)
    with pytest.raises(ValueError, match="p_j0|p_j"):
        schedules.polynomial_decay(bad_pj0, 100)
    with pytest.raises(ValueError, match="p_j0|p_j"):
        schedules.step_decay(bad_pj0, 100, drop_every=10)
    with pytest.raises(ValueError, match="p_j0|p_j"):
        schedules.linear_to_zero(bad_pj0, 100)


def test_schedule_edge_param_validation():
    with pytest.raises(ValueError, match="num_steps"):
        schedules.constant(0.3, 0)
    with pytest.raises(ValueError, match="t0"):
        schedules.polynomial_decay(0.3, 10, t0=0)
    with pytest.raises(ValueError, match="power"):
        schedules.polynomial_decay(0.3, 10, power=-1.0)
    with pytest.raises(ValueError, match="factor"):
        schedules.step_decay(0.3, 10, drop_every=2, factor=0.0)
    with pytest.raises(ValueError, match="zero_at"):
        schedules.linear_to_zero(0.3, 10, zero_at=1.5)


def test_schedules_valid_outputs_in_range():
    """Validation must not perturb valid outputs: every schedule stays a
    probability sequence, boundary p_j0 values included."""
    for sched in (
        schedules.constant(1.0, 32),
        schedules.constant(0.0, 32),
        schedules.polynomial_decay(1.0, 32, power=2.0, t0=3),
        schedules.step_decay(1.0, 32, drop_every=7, factor=1.0),
        schedules.linear_to_zero(1.0, 32, zero_at=1.0),
    ):
        assert sched.shape == (32,) and sched.dtype == np.float32
        assert float(sched.min()) >= 0.0 and float(sched.max()) <= 1.0
